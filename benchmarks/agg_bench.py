"""Per-round aggregation wall-clock: legacy per-layer loop vs the batched
vmapped server pipeline, on both thin-SVD routes (LAPACK ``svd`` / Gram
``gram``).

The FLoRIST pitch is that server-side decomposition is cheap; this measures
what the *dispatch* around it costs.  The legacy loop runs one eager
``florist_core_stacked`` per (leaf, layer) — re-tracing and host-syncing on
every iteration — while the batched pipeline compiles one vmapped call per
bucket of equal-shaped leaves and transfers spectra/ranks once.

Config: 3 leaves × L layers, heterogeneous client ranks (4/8/16), the
3-leaf × 8-layer shape from the issue.  Emits JSON for CI artifacts::

    PYTHONPATH=src python benchmarks/agg_bench.py --smoke --json agg.json
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import numpy as np

from repro.core.aggregators import make_aggregator

HETERO_RANKS = (4, 8, 16)


def make_clients(rng, *, layers: int, leaves: int, m: int, n: int):
    trees, weights = [], []
    for r in HETERO_RANKS:
        t = {}
        for i in range(leaves):
            t[f"leaf{i}"] = {
                "A": np.asarray(rng.normal(size=(layers, r, n)), np.float32),
                "B": np.asarray(rng.normal(size=(layers, m, r)), np.float32),
                "scale": np.ones((layers,), np.float32),
            }
        trees.append(t)
    weights = list(rng.dirichlet([1.0] * len(HETERO_RANKS)))
    return trees, weights


def time_round(agg, trees, weights, *, warmup: int, iters: int) -> float:
    """Median wall-clock (ms) of one full streaming round (add_client ×K +
    finalize, blocking on all outputs)."""

    def once():
        agg.begin_round()
        for t, w in zip(trees, weights):
            agg.add_client(t, w)
        res = agg.finalize()
        jax.block_until_ready(
            jax.tree.leaves(res.global_adapters))
        return res

    for _ in range(warmup):
        once()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        once()
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(statistics.median(ts))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small config + few iters (CI)")
    ap.add_argument("--json", default="", help="write results to this path")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--iters", type=int, default=0)
    args = ap.parse_args()

    layers = args.layers or 8
    leaves = 3
    m, n = (64, 48) if args.smoke else (256, 192)
    iters = args.iters or (3 if args.smoke else 5)
    tau = 0.9

    rng = np.random.default_rng(0)
    trees, weights = make_clients(rng, layers=layers, leaves=leaves, m=m, n=n)

    results = []
    for pipeline in ("loop", "batched"):
        for svd_method in ("svd", "gram"):
            agg = make_aggregator("florist", tau=tau, svd_method=svd_method,
                                  pipeline=pipeline)
            ms = time_round(agg, trees, weights, warmup=1, iters=iters)
            results.append({"pipeline": pipeline, "svd_method": svd_method,
                            "ms_per_round": round(ms, 3)})
            print(f"{pipeline:8s} {svd_method:5s} {ms:9.2f} ms/round")

    def best(pipeline):
        return min(r["ms_per_round"] for r in results
                   if r["pipeline"] == pipeline)

    speedup = best("loop") / best("batched")
    print(f"speedup (batched vs loop, best route): {speedup:.2f}x")

    report = {
        "config": {"layers": layers, "leaves": leaves, "m": m, "n": n,
                   "client_ranks": list(HETERO_RANKS), "tau": tau,
                   "iters": iters, "smoke": bool(args.smoke),
                   "backend": jax.default_backend()},
        "results": results,
        "speedup_batched_vs_loop": round(speedup, 2),
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
