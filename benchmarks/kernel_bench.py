"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode (not
meaningful to time), so we time the jit-compiled XLA reference paths (the
actual CPU execution path) and report the kernels' analytic FLOPs/bytes as
`derived` (the roofline inputs for the TPU target)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ref


def run():
    rng = np.random.default_rng(0)
    rows = []

    # fused LoRA matmul: M=2048, d=2048, r=16
    M, D, O, R = 2048, 2048, 2048, 16
    x = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, O)) * 0.02, jnp.float32)
    a = jnp.asarray(rng.normal(size=(R, D)) * 0.02, jnp.float32)
    b = jnp.asarray(rng.normal(size=(O, R)) * 0.02, jnp.float32)
    t = timeit(jax.jit(lambda *ar: ref.lora_matmul_ref(*ar, 0.5)), x, w, a, b)
    flops = 2 * M * D * O + 2 * M * R * (D + O)
    rows.append({"name": "kernel/lora_matmul", "us_per_call": f"{t:.0f}",
                 "derived": f"flops={flops:.3e};tpu_est_us={flops/197e12*1e6:.1f}"})

    # flash attention: B=1,S=1024,H=8,K=2,hd=64
    q = jnp.asarray(rng.normal(size=(1, 1024, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)), jnp.float32)
    t = timeit(jax.jit(ref.flash_attention_ref), q, k, v)
    flops = 2 * 2 * 1024 * 1024 * 8 * 64
    rows.append({"name": "kernel/flash_attention", "us_per_call": f"{t:.0f}",
                 "derived": f"flops={flops:.3e}"})

    # wkv6: B=1,S=512,H=8,hd=64
    r_ = jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.float32)
    w_ = -jnp.exp(jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.float32))
    u = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    t = timeit(jax.jit(ref.wkv6_ref), r_, r_, r_, w_, u)
    flops = 4 * 512 * 8 * 64 * 64
    rows.append({"name": "kernel/wkv6", "us_per_call": f"{t:.0f}",
                 "derived": f"flops={flops:.3e}"})

    # adapter gram: m=8192, r=160
    xg = jnp.asarray(rng.normal(size=(8192, 160)), jnp.float32)
    t = timeit(jax.jit(ref.adapter_gram_ref), xg)
    flops = 2 * 8192 * 160 * 160
    rows.append({"name": "kernel/adapter_gram", "us_per_call": f"{t:.0f}",
                 "derived": f"flops={flops:.3e}"})
    return rows


if __name__ == "__main__":
    emit(run())
