"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode (not
meaningful to time), so we time the jit-compiled XLA paths that actually
execute on CPU — the references for the classic kernels, and dense-vs-
streamed for the decode kernels — and report the kernels' analytic
FLOPs/bytes as `derived` (the roofline inputs for the TPU target).

The decode section is the ring-flash-decode acceptance harness:

  * dense vs streamed decode attention timings over a ring cache
    (fp32 and int8), with analytic per-step HBM bytes for both paths —
    dense pays the (B,H,C,cap) score tensor, the (B,C,cap) mask, and (for
    int8) a full-precision cache copy; streamed pays none of them;
  * a live-memory/HLO check on the JITTED SERVE STEP: the compiled
    ``decode_impl="streamed"`` executable must contain neither a
    (B,H,C,cap) (nor (B,K,g,C,cap)) score buffer nor a dense (B,C,cap)
    mask, and its XLA temp allocation must not exceed the dense path's.
    Violations raise — CI runs this file.

    PYTHONPATH=src python benchmarks/kernel_bench.py --json BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.common.config import ModelConfig
from repro.kernels import ref
from repro.models import transformer as T
from repro.models.attention_core import ring_flash_decode
from repro.serve.kvcache import quant
from repro.train.step import make_serve_step

DEC_MODEL = ModelConfig(name="kernelbench-tiny", family="dense", num_layers=2,
                        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                        d_ff=128, vocab_size=256, dtype="float32")


def run():
    rng = np.random.default_rng(0)
    rows = []

    # fused LoRA matmul: M=2048, d=2048, r=16
    M, D, O, R = 2048, 2048, 2048, 16
    x = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, O)) * 0.02, jnp.float32)
    a = jnp.asarray(rng.normal(size=(R, D)) * 0.02, jnp.float32)
    b = jnp.asarray(rng.normal(size=(O, R)) * 0.02, jnp.float32)
    t = timeit(jax.jit(lambda *ar: ref.lora_matmul_ref(*ar, 0.5)), x, w, a, b)
    flops = 2 * M * D * O + 2 * M * R * (D + O)
    rows.append({"name": "kernel/lora_matmul", "us_per_call": f"{t:.0f}",
                 "derived": f"flops={flops:.3e};tpu_est_us={flops/197e12*1e6:.1f}"})

    # flash attention: B=1,S=1024,H=8,K=2,hd=64
    q = jnp.asarray(rng.normal(size=(1, 1024, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)), jnp.float32)
    t = timeit(jax.jit(ref.flash_attention_ref), q, k, v)
    flops = 2 * 2 * 1024 * 1024 * 8 * 64
    rows.append({"name": "kernel/flash_attention", "us_per_call": f"{t:.0f}",
                 "derived": f"flops={flops:.3e}"})

    # wkv6: B=1,S=512,H=8,hd=64
    r_ = jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.float32)
    w_ = -jnp.exp(jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.float32))
    u = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    t = timeit(jax.jit(ref.wkv6_ref), r_, r_, r_, w_, u)
    flops = 4 * 512 * 8 * 64 * 64
    rows.append({"name": "kernel/wkv6", "us_per_call": f"{t:.0f}",
                 "derived": f"flops={flops:.3e}"})

    # adapter gram: m=8192, r=160
    xg = jnp.asarray(rng.normal(size=(8192, 160)), jnp.float32)
    t = timeit(jax.jit(ref.adapter_gram_ref), xg)
    flops = 2 * 8192 * 160 * 160
    rows.append({"name": "kernel/adapter_gram", "us_per_call": f"{t:.0f}",
                 "derived": f"flops={flops:.3e}"})
    return rows


def decode_bytes(B, C, H, K, hd, cap, block, int8: bool):
    """Analytic per-step HBM traffic (bytes) of one decode attention layer.

    Both paths stream the raw cache once (GQA: once per kv head) and write
    the (B,C,H,hd) fp32 output.  The dense path additionally round-trips
    the (B,H,C,cap) fp32 score tensor (written by the scores matmul, read +
    re-written by masking/softmax, read by the value matmul), materializes
    the (B,C,cap) bool ring mask, and — when the cache is int8 — a
    full-precision (bf16) cache copy.  The streamed path's score/mask tiles
    are (C, block) per grid step and live in VMEM/registers only.
    """
    elt = 1 if int8 else 4
    cache = 2 * B * cap * K * hd * elt + (2 * B * cap * K * 4 if int8 else 0)
    out = B * C * H * hd * 4
    common = cache + out
    scores = B * H * C * cap * 4
    mask = B * C * cap
    dense = common + 4 * scores + mask + (2 * B * cap * K * hd * 2 if int8 else 0)
    streamed = common
    return {"dense": dense, "streamed": streamed,
            "live_score_tile": {"dense": scores, "streamed": B * H * C * block * 4}}


def bench_decode(rng, B=8, C=8, H=8, K=2, hd=64, cap=2048, block=128):
    """Time dense vs streamed decode attention over a populated ring cache
    (fp32 + int8) and report speedups + analytic HBM bytes."""
    q = jnp.asarray(rng.normal(size=(B, C, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, cap, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, cap, K, hd)), jnp.float32)
    pos = jnp.full((B,), cap + 37, jnp.int32)          # wrapped ring
    length = jnp.full((B,), cap, jnp.int32)
    n = jnp.full((B,), C, jnp.int32)
    kq, ks = quant(k)
    vq, vs = quant(v)

    arms = {
        "dense_fp32": (jax.jit(ref.ring_decode_ref),
                       (q, k, v, pos, length, n)),
        "streamed_fp32": (jax.jit(functools.partial(ring_flash_decode,
                                                    block=block)),
                          (q, k, v, pos, length, n)),
        "dense_int8": (jax.jit(lambda *a: ref.ring_decode_ref(
            *a, k_scale=ks, v_scale=vs)), (q, kq, vq, pos, length, n)),
        "streamed_int8": (jax.jit(lambda *a: ring_flash_decode(
            *a, k_scale=ks, v_scale=vs, block=block)),
            (q, kq, vq, pos, length, n)),
    }
    out = {"shape": {"B": B, "C": C, "H": H, "K": K, "hd": hd, "cap": cap,
                     "block": block}}
    for name, (fn, args) in arms.items():
        us = timeit(fn, *args)
        int8 = name.endswith("int8")
        impl = name.split("_")[0]
        bts = decode_bytes(B, C, H, K, hd, cap, block, int8)
        out[name] = {"us_per_call": round(us, 1),
                     "analytic_hbm_bytes": bts[impl],
                     "live_score_bytes": bts["live_score_tile"][impl]}
    for p in ("fp32", "int8"):
        out[f"speedup_streamed_vs_dense_{p}"] = round(
            out[f"dense_{p}"]["us_per_call"]
            / out[f"streamed_{p}"]["us_per_call"], 2)
        out[f"hbm_bytes_ratio_{p}"] = round(
            out[f"dense_{p}"]["analytic_hbm_bytes"]
            / out[f"streamed_{p}"]["analytic_hbm_bytes"], 2)
    return out


def serve_step_live_memory_check(B=4, C=8, cap=256):
    """Compile the jitted serve step per decode_impl and prove the streamed
    executable materializes neither the (B,H,C,cap)/(B,K,g,C,cap) score
    tensor nor the dense (B,C,cap) mask, and allocates no more XLA temp
    memory than the dense path.  Raises on violation."""
    cfg = DEC_MODEL
    H, K = cfg.num_heads, cfg.num_kv_heads
    score_shapes = [f"f32[{B},{H},{C},{cap}]",
                    f"f32[{B},{K},{H // K},{C},{cap}]"]
    mask_shape = f"pred[{B},{C},{cap}]"
    params = T.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((B, C), jnp.int32),
             "n_tokens": jnp.full((B,), C, jnp.int32)}
    report = {"shape": {"B": B, "C": C, "cap": cap, "H": H, "K": K},
              "checked_buffers": score_shapes + [mask_shape]}
    for impl in ("dense", "streamed"):
        cache = T.init_cache(cfg, B, cap, jnp.float32, prefill_chunk=C)
        comp = jax.jit(make_serve_step(cfg, impl)).lower(
            params, None, cache, batch).compile()
        txt = comp.as_text()
        found = [s for s in score_shapes + [mask_shape] if s in txt]
        try:
            temp = int(comp.memory_analysis().temp_size_in_bytes)
        except Exception:                      # backend without the API
            temp = None
        report[impl] = {"materialized_buffers": found,
                        "xla_temp_bytes": temp}
    assert report["dense"]["materialized_buffers"], \
        "sanity: dense path should materialize the score/mask buffers"
    assert not report["streamed"]["materialized_buffers"], \
        f"streamed serve step materializes {report['streamed']}"
    dt, st = (report["dense"]["xla_temp_bytes"],
              report["streamed"]["xla_temp_bytes"])
    if dt is not None and st is not None:
        assert st <= dt, f"streamed temp {st} > dense temp {dt}"
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="", help="write results to this path")
    ap.add_argument("--cap", type=int, default=2048,
                    help="ring capacity for the decode timing arms")
    args = ap.parse_args()

    rows = run()
    emit(rows)

    rng = np.random.default_rng(0)
    decode = bench_decode(rng, cap=args.cap)
    for p in ("fp32", "int8"):
        print(f"decode[{p}]: dense {decode[f'dense_{p}']['us_per_call']}us "
              f"vs streamed {decode[f'streamed_{p}']['us_per_call']}us "
              f"({decode[f'speedup_streamed_vs_dense_{p}']}x, analytic HBM "
              f"{decode[f'hbm_bytes_ratio_{p}']}x less)")

    live = serve_step_live_memory_check()
    print(f"serve-step live-memory check: dense materializes "
          f"{live['dense']['materialized_buffers']}, streamed none "
          f"(temp {live['dense']['xla_temp_bytes']} -> "
          f"{live['streamed']['xla_temp_bytes']} bytes)")

    if args.json:
        report = {
            "backend": jax.default_backend(),
            "kernels": rows,
            "decode": decode,
            "serve_step_live_memory": live,
        }
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
