"""Table 2 analogue: accuracy vs communication efficiency for all five
methods, homogeneous and heterogeneous client ranks, on the synthetic
federated instruction task (the offline stand-in for MMLU×{Dolly,Alpaca,
Wizard}).

Claim validated: FLoRIST matches-or-beats baseline accuracy at the best
communication efficiency (lowest download rank)."""
from __future__ import annotations

from benchmarks.common import FAST, bench_fed, emit


def run():
    rows = []
    for heter in (False, True):
        tag = "heter" if heter else "homo"
        results = {}
        for method in ("florist", "fedit", "ffa", "flora", "flexlora"):
            hist, tr = bench_fed(method, heterogeneous=heter)
            eff = 1.0 / max(1.0, hist[-1].download_rank)
            results[method] = (hist[-1].eval_acc, eff, hist[-1].eval_loss)
            rows.append({
                "name": f"table2/{tag}/{method}",
                "us_per_call": f"{hist[-1].eval_loss:.4f}",
                "derived": f"acc={hist[-1].eval_acc:.3f};eff={eff:.2e};"
                           f"down_rank={hist[-1].download_rank:.0f}",
            })
        # paper claim: florist most download-efficient
        effs = {m: r[1] for m, r in results.items()}
        best = max(effs, key=effs.get)
        rows.append({"name": f"table2/{tag}/most_efficient",
                     "us_per_call": "",
                     "derived": f"{best};florist_wins={best == 'florist'}"})
    return rows


if __name__ == "__main__":
    emit(run())
