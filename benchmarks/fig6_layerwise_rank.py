"""Figure 6: layer-wise optimal rank per projection (q_proj vs v_proj) in a
heterogeneous round — intrinsic dimensionality varies across depth and
across projections."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_fed, emit


def run():
    hist, tr = bench_fed("florist", heterogeneous=True, tau=0.9, rounds=2)
    agg = tr.global_state
    rows = []
    per_proj = {}
    for path, ranks in agg.ranks.items():
        proj = path[-1]
        per_proj[proj] = ranks
        rows.append({"name": f"fig6/{proj}", "us_per_call": "",
                     "derived": "ranks=" + "|".join(map(str, ranks))})
    if "wq" in per_proj and "wv" in per_proj:
        rows.append({
            "name": "fig6/summary", "us_per_call": "",
            "derived": (f"mean_q={np.mean(per_proj['wq']):.1f};"
                        f"mean_v={np.mean(per_proj['wv']):.1f};"
                        f"varies_across_layers="
                        f"{len(set(per_proj['wq'])) > 1 or len(set(per_proj['wv'])) > 1}"),
        })
    return rows


if __name__ == "__main__":
    emit(run())
