"""Benchmark harness: one module per paper table/figure + kernels +
roofline.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only table3,fig5] [--fast]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

MODULES = [
    ("table3", "benchmarks.table3_comm_cost"),
    ("table4", "benchmarks.table4_server_flops"),
    ("fig2", "benchmarks.fig2_spectrum"),
    ("fig5", "benchmarks.fig5_rank_vs_tau"),
    ("fig6", "benchmarks.fig6_layerwise_rank"),
    ("kernels", "benchmarks.kernel_bench"),
    ("table2", "benchmarks.table2_accuracy_efficiency"),
    ("fig7", "benchmarks.fig7_tau_vs_quality"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    if args.fast:
        os.environ["BENCH_FAST"] = "1"
    only = set(args.only.split(",")) if args.only else None

    import importlib
    print("name,us_per_call,derived")
    failures = []
    for tag, modname in MODULES:
        if only and tag not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            rows = mod.run()
            for r in rows:
                print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
            print(f"#{tag} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures.append(tag)
            traceback.print_exc()
    # roofline table from dry-run records, if present
    try:
        from benchmarks.summarize_dryrun import rows as roof_rows
        for r in roof_rows():
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},,"
                  f"dominant={r['dominant']};compute_s={r['compute_s']:.4f};"
                  f"memory_s={r['memory_s']:.4f};collective_s={r['collective_s']:.4f};"
                  f"mem_gib={r['mem_gib']:.2f}")
    except Exception:
        pass
    if failures:
        print(f"FAILED benchmarks: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
