"""End-to-end driver: federated LoRA fine-tuning with all five aggregation
methods on a configurable model, several hundred local steps total.

  PYTHONPATH=src python examples/federated_finetune.py \
      [--method florist] [--rounds 20] [--tau 0.9] [--heter] [--model 100m] \
      [--runner cohort] [--scheduler async] [--codec bf16]

``--model 100m`` builds a ~100M-parameter decoder (12L × 768) — the
paper-style end-to-end run (slow on CPU; the default 'tiny' profile runs in
a couple of minutes).  ``--runner cohort`` trains each equal-rank cohort in
one vmapped call; ``--scheduler`` swaps the participation semantics;
``--codec`` picks the wire serialization whose measured bytes are printed
per round (see :mod:`repro.core.runtime`).
"""
import argparse
import time

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
import repro.core.distributed  # noqa: F401  (registers florist_sharded)
from repro.core.aggregators import available_aggregators
from repro.core.federated import FederatedTrainer
from repro.core.runtime import (available_codecs, available_runners,
                                available_schedulers)

PROFILES = {
    "tiny": ModelConfig(name="fed-tiny", family="dense", num_layers=4,
                        d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
                        d_ff=256, vocab_size=512, dtype="float32"),
    "20m": ModelConfig(name="fed-20m", family="dense", num_layers=8,
                       d_model=384, num_heads=6, num_kv_heads=2, head_dim=64,
                       d_ff=1024, vocab_size=2048, dtype="float32"),
    "100m": ModelConfig(name="fed-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
                        d_ff=2048, vocab_size=8192, dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="florist",
                    choices=available_aggregators())
    ap.add_argument("--model", default="tiny", choices=list(PROFILES))
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--tau", type=float, default=0.9)
    ap.add_argument("--heter", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--runner", default="sequential",
                    choices=available_runners())
    ap.add_argument("--scheduler", default="sync",
                    choices=available_schedulers())
    ap.add_argument("--codec", default="fp32", choices=available_codecs())
    args = ap.parse_args()

    cfg = PROFILES[args.model]
    fed = FedConfig(num_clients=40, clients_per_round=8, method=args.method,
                    tau=args.tau, homogeneous_rank=16,
                    heterogeneous=args.heter,
                    rank_distribution=((4, 16), (8, 8), (16, 8), (32, 4), (64, 4)),
                    zero_padding=args.heter and args.method in ("fedit", "ffa"),
                    seed=args.seed)
    trainer = FederatedTrainer(cfg, fed, LoRAConfig(rank=16, alpha=16.0),
                               OptimConfig(lr=3e-4), batch_size=8,
                               local_steps=args.local_steps, seq_len=64,
                               runner=args.runner, scheduler=args.scheduler,
                               transport=args.codec)
    total_steps = args.rounds * fed.clients_per_round * args.local_steps
    print(f"== federated fine-tune: {cfg.name} ({cfg.param_count():,} params), "
          f"method={args.method}, runner={args.runner}, "
          f"scheduler={args.scheduler}, codec={args.codec}, "
          f"{args.rounds} rounds (~{total_steps} local steps total) ==")
    t0 = time.time()
    for rnd in range(args.rounds):
        rec = trainer.run_round(rnd)
        print(f"[{time.time()-t0:7.1f}s] round {rnd:3d} "
              f"loss={rec.eval_loss:.4f} acc={rec.eval_acc:.3f} "
              f"down_rank={rec.download_rank:.0f} "
              f"wire_up_MB={rec.upload_bytes / 2**20:.2f} "
              f"wire_down_MB={rec.download_bytes / 2**20:.2f} "
              f"({rec.wall_secs:.2f}s/round)")
    print("done.")


if __name__ == "__main__":
    main()
