"""End-to-end driver: federated LoRA fine-tuning with all five aggregation
methods on a configurable model, several hundred local steps total.

  PYTHONPATH=src python examples/federated_finetune.py \
      [--method florist] [--rounds 20] [--tau 0.9] [--heter] [--model 100m] \
      [--runner cohort] [--scheduler async] [--codec bf16] \
      [--clients 1024] [--participation 0.05] [--rank-policy resource] \
      [--dp-clip 1.0] [--dp-epsilon 8]

``--model 100m`` builds a ~100M-parameter decoder (12L × 768) — the
paper-style end-to-end run (slow on CPU; the default 'tiny' profile runs in
a couple of minutes).  ``--runner cohort`` trains each equal-rank cohort in
one vmapped call; ``--scheduler`` swaps the participation semantics;
``--codec`` picks the wire serialization whose measured bytes are printed
per round (see :mod:`repro.core.runtime`).

For the population-scale simulation, ``--clients 1024 --participation
0.05 --runner sharded_cohort`` samples ~51 participants per round from a
seed-deterministic rng and trains them in mesh-sharded cohort blocks
(run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to
shard over 8 virtual devices).  ``--rank-policy resource`` adapts each
task's LoRA rank to a cyclic client-budget profile; ``--dp-clip`` /
``--dp-sigma`` privatize every upload on the wire (``--dp-epsilon``
calibrates σ from a per-round ε instead).

Long runs survive crashes: ``--checkpoint /tmp/fed.ckpt`` saves the
round-boundary state atomically every round, and re-running with
``--resume`` continues bit-identically from the last save.
``--validation {off,screen,full}`` / ``--min-clients`` configure the
server's update gate (screen rejects NaN/Inf and shape violations;
full additionally quarantines norm outliers).
"""
import argparse
import os
import time

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
import repro.core.distributed  # noqa: F401  (registers florist_sharded)
from repro.core.aggregators import available_aggregators
from repro.core.federated import FederatedTrainer
from repro.core.privacy import noise_multiplier_for_epsilon
from repro.core.runtime import (SampledScheduler, available_codecs,
                                available_rank_policies, available_runners,
                                available_schedulers)

PROFILES = {
    "tiny": ModelConfig(name="fed-tiny", family="dense", num_layers=4,
                        d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
                        d_ff=256, vocab_size=512, dtype="float32"),
    "20m": ModelConfig(name="fed-20m", family="dense", num_layers=8,
                       d_model=384, num_heads=6, num_kv_heads=2, head_dim=64,
                       d_ff=1024, vocab_size=2048, dtype="float32"),
    "100m": ModelConfig(name="fed-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
                        d_ff=2048, vocab_size=8192, dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="florist",
                    choices=available_aggregators())
    ap.add_argument("--model", default="tiny", choices=list(PROFILES))
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--tau", type=float, default=0.9)
    ap.add_argument("--heter", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--runner", default="sequential",
                    choices=available_runners())
    ap.add_argument("--scheduler", default="sync",
                    choices=available_schedulers())
    ap.add_argument("--codec", default="fp32", choices=available_codecs())
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--participation", type=float, default=0.0,
                    help="sampled-scheduler fraction (overrides --scheduler)")
    ap.add_argument("--rank-policy", default="static",
                    choices=available_rank_policies())
    ap.add_argument("--dp-clip", type=float, default=0.0)
    ap.add_argument("--dp-sigma", type=float, default=0.0)
    ap.add_argument("--dp-epsilon", type=float, default=0.0,
                    help="per-round epsilon -> sigma (overrides --dp-sigma)")
    ap.add_argument("--checkpoint", default="",
                    help="round-boundary checkpoint path (atomic writes)")
    ap.add_argument("--checkpoint-every", type=int, default=1)
    ap.add_argument("--resume", action="store_true",
                    help="resume from --checkpoint (bit-identical replay)")
    ap.add_argument("--validation", default="screen",
                    choices=["off", "screen", "full"])
    ap.add_argument("--min-clients", type=int, default=1,
                    help="round quorum: accepted updates required to fold")
    args = ap.parse_args()

    scheduler = args.scheduler
    if args.participation:
        scheduler = SampledScheduler(fraction=args.participation)
    dp_sigma = args.dp_sigma
    if args.dp_epsilon:
        dp_sigma = noise_multiplier_for_epsilon(args.dp_epsilon)

    cfg = PROFILES[args.model]
    c = args.clients
    # the tiny heavy-tail profile, scaled to --clients (counts must sum to c)
    dist = ((4, 4 * c // 10), (8, 2 * c // 10), (16, 2 * c // 10), (32, c // 10),
            (64, c - (4 * c // 10) - 2 * (2 * c // 10) - c // 10))
    fed = FedConfig(num_clients=c, clients_per_round=8, method=args.method,
                    tau=args.tau, homogeneous_rank=16,
                    heterogeneous=args.heter,
                    rank_distribution=dist,
                    zero_padding=args.heter and args.method in ("fedit", "ffa"),
                    seed=args.seed)
    trainer = FederatedTrainer(cfg, fed, LoRAConfig(rank=16, alpha=16.0),
                               OptimConfig(lr=3e-4), batch_size=8,
                               local_steps=args.local_steps, seq_len=64,
                               dp_clip=args.dp_clip, dp_sigma=dp_sigma,
                               runner=args.runner, scheduler=scheduler,
                               rank_policy=args.rank_policy,
                               transport=args.codec,
                               validation=args.validation,
                               min_clients=args.min_clients)
    per_round = max(1, round(args.participation * c)) if args.participation \
        else fed.clients_per_round
    total_steps = args.rounds * per_round * args.local_steps
    sched_name = scheduler if isinstance(scheduler, str) else scheduler.name
    print(f"== federated fine-tune: {cfg.name} ({cfg.param_count():,} params), "
          f"method={args.method}, runner={args.runner}, "
          f"scheduler={sched_name}, codec={args.codec}, "
          f"{args.rounds} rounds (~{total_steps} local steps total) ==")
    start = 0
    if args.resume and args.checkpoint and os.path.exists(args.checkpoint):
        start = trainer.restore_checkpoint(args.checkpoint)
        print(f"== resumed from {args.checkpoint} at round {start} ==")
    t0 = time.time()
    for rnd in range(start, args.rounds):
        rec = trainer.run_round(rnd)
        print(f"[{time.time()-t0:7.1f}s] round {rnd:3d} "
              f"loss={rec.eval_loss:.4f} acc={rec.eval_acc:.3f} "
              f"down_rank={rec.download_rank:.0f} "
              f"wire_up_MB={rec.upload_bytes / 2**20:.2f} "
              f"wire_down_MB={rec.download_bytes / 2**20:.2f} "
              f"({rec.wall_secs:.2f}s/round)")
        if args.checkpoint and (rnd + 1) % args.checkpoint_every == 0:
            trainer.save_checkpoint(args.checkpoint, rnd + 1)
    print("done.")


if __name__ == "__main__":
    main()
