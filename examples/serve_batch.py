"""Batched serving of a fine-tuned (base + global LoRA) model: chunked
prefill through the cached sequence path, then greedy batched decode — the
inference path the decode_32k / long_500k dry-run shapes exercise.

The KV cache carries **per-slot** positions, so prefill feeds whole prompt
chunks (``--prefill-chunk`` tokens per jitted call) instead of one token per
step, and heterogeneous batch rows could ride different ring offsets.

  PYTHONPATH=src python examples/serve_batch.py [--arch qwen2-0.5b] \
      [--batch 4] [--prompt-len 16] [--gen 24] [--window 0] \
      [--prefill-chunk 8] [--int8-cache]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config, lora_targets
from repro.models import transformer as T
from repro.peft.lora import init_lora
from repro.train.step import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window size (0 = full attention)")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens fed per jitted prefill call")
    ap.add_argument("--int8-cache", action="store_true")
    ap.add_argument("--decode-impl", default="dense",
                    choices=["dense", "streamed", "kernel"],
                    help="attention interior: dense oracle, streamed "
                         "ring-flash-decode (XLA), or the Pallas kernel")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.window:
        cfg = cfg.replace(sliding_window=args.window)
    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    adapters = init_lora(params, lora_targets(cfg), 8, 16.0, key, sigma=0.05)

    B = args.batch
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(4, cfg.vocab_size, (B, args.prompt_len)))

    serve = jax.jit(make_serve_step(cfg, decode_impl=args.decode_impl))
    kv_dtype = jnp.int8 if args.int8_cache else jnp.dtype(cfg.dtype)
    C = max(1, min(args.prefill_chunk, args.prompt_len))
    cache = T.init_cache(cfg, B, capacity=args.prompt_len + args.gen,
                         kv_dtype=kv_dtype, prefill_chunk=C)
    print(f"== serving {cfg.name}: batch={B}, prompt={args.prompt_len}, "
          f"gen={args.gen}, window={args.window or 'full'}, "
          f"cache={kv_dtype}, prefill_chunk={C}, "
          f"decode_impl={args.decode_impl} ==")
    # chunked prefill: whole prompt chunks through the cached sequence path
    t0 = time.time()
    n_calls = 0
    for t in range(0, args.prompt_len, C):
        chunk = prompts[:, t: t + C]
        n = jnp.full((B,), chunk.shape[1], jnp.int32)
        logits, cache = serve(params, adapters, cache,
                              {"tokens": chunk, "n_tokens": n})
        n_calls += 1
    print(f"prefill: {args.prompt_len} tokens in {n_calls} calls, "
          f"{time.time()-t0:.2f}s")

    generated = []
    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.time()
    for _ in range(args.gen):
        generated.append(tok)
        logits, cache = serve(params, adapters, cache, {"tokens": tok})
        tok = jnp.argmax(logits, -1)[:, None]
    dt = time.time() - t0
    gen = jnp.concatenate(generated, axis=1)
    print(f"decode: {args.gen} steps × batch {B} in {dt:.2f}s "
          f"({B*args.gen/dt:.1f} tok/s on CPU)")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
