"""Sweep the energy threshold τ (paper Figs. 5 & 7): total global rank and
eval quality vs τ, on the synthetic federated task.

  PYTHONPATH=src python examples/threshold_sweep.py [--rounds 6]
"""
import argparse

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.aggregators import make_aggregator
from repro.core.federated import FederatedTrainer

CFG = ModelConfig(name="sweep-tiny", family="dense", num_layers=4, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--taus", default="0.6,0.8,0.9,0.95,0.99")
    args = ap.parse_args()

    print(f"{'tau':>6s} {'total_rank':>11s} {'eff(1/rank)':>12s} "
          f"{'eval_loss':>10s} {'eval_acc':>9s}")
    for tau in (float(t) for t in args.taus.split(",")):
        fed = FedConfig(num_clients=20, clients_per_round=5, method="florist",
                        tau=tau, homogeneous_rank=8, seed=0)
        # the strategy is injectable: build it explicitly and hand it to the
        # trainer (same as what fed.method would construct via the registry)
        tr = FederatedTrainer(CFG, fed, LoRAConfig(rank=8, alpha=8.0),
                              OptimConfig(lr=3e-3), batch_size=8,
                              local_steps=4, seq_len=32,
                              aggregator=make_aggregator("florist", tau=tau))
        hist = tr.run(args.rounds)
        last = hist[-1]
        rank = last.global_rank_total
        print(f"{tau:6.2f} {rank:11d} {1.0/max(rank,1):12.2e} "
              f"{last.eval_loss:10.4f} {last.eval_acc:9.3f}")


if __name__ == "__main__":
    main()
