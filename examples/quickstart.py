"""Quickstart: one federated FLoRIST round on a tiny model, end to end.

  PYTHONPATH=src python examples/quickstart.py

Walks through the public API: build a model, give every client a LoRA
adapter, fine-tune locally, aggregate with singular-value thresholding,
inspect the chosen ranks and the communication savings.
"""
import jax
import jax.numpy as jnp

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core import costs as C
from repro.core.federated import FederatedTrainer


def main():
    cfg = ModelConfig(name="quickstart-tiny", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=256, dtype="float32")
    fed = FedConfig(num_clients=10, clients_per_round=4, method="florist",
                    tau=0.9, homogeneous_rank=8, seed=0)
    trainer = FederatedTrainer(cfg, fed, LoRAConfig(rank=8, alpha=8.0),
                               OptimConfig(lr=3e-3), batch_size=8,
                               local_steps=4, seq_len=32)

    print("== FLoRIST quickstart ==")
    print(f"model: {cfg.name}  params={cfg.param_count():,}")
    print(f"clients: {fed.num_clients} (sample {fed.clients_per_round}/round), "
          f"Dirichlet α={fed.dirichlet_alpha}, τ={fed.tau}")
    for rnd in range(3):
        rec = trainer.run_round(rnd)
        print(f"round {rnd}: eval_loss={rec.eval_loss:.4f} "
              f"acc={rec.eval_acc:.3f} "
              f"download_rank={rec.download_rank:.0f} "
              f"(stacked would be "
              f"{fed.clients_per_round * fed.homogeneous_rank * 2 * cfg.num_layers})")
    agg = trainer.global_state
    print("\nper-layer kept ranks (energy threshold τ=0.9):")
    for path, ranks in agg.ranks.items():
        print(f"  {'/'.join(map(str, path))}: {ranks}")
    last = trainer.history[-1]
    print(f"\ndownload cost this round: "
          f"{C.mb(last.download_params):.3f} MB "
          f"(upload {C.mb(last.upload_params):.3f} MB) — analytic FP16")
    # the runtime also *measures* serialized bytes on the wire (fp32 codec
    # here; swap transport="bf16"/"int8" on the trainer to compress)
    print(f"measured on the wire:     "
          f"{C.wire_mb(last.download_bytes):.3f} MB down / "
          f"{C.wire_mb(last.upload_bytes):.3f} MB up "
          f"({last.wall_secs:.2f}s/round)")


if __name__ == "__main__":
    main()
