"""FLoRIST end to end: federate a tiny model, then SERVE the global adapter.

This is the deployment flow the paper's output feeds: `launch/fed.py` (or
`FederatedTrainer` directly) produces ONE pair of global low-rank adapters
shared by all clients; `ServeEngine` mounts them next to the frozen base and
serves a continuous batch of requests — per-slot KV positions, chunked
prefill, jitted decode step.

  PYTHONPATH=src python examples/serve_federated.py [--rounds 2] \
      [--requests 6] [--batch-slots 2] [--temperature 0.0]
"""
import argparse

import numpy as np

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.federated import FederatedTrainer
from repro.serve.engine import SamplingParams, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch-slots", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--decode-impl", default="streamed",
                    choices=["dense", "streamed", "kernel"],
                    help="serving attention interior (streamed = "
                         "ring-flash-decode hot loop)")
    args = ap.parse_args()

    cfg = ModelConfig(name="serve-fed-tiny", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=256, dtype="float32")
    fed = FedConfig(num_clients=8, clients_per_round=4, method="florist",
                    tau=0.9, homogeneous_rank=8, seed=0)
    trainer = FederatedTrainer(cfg, fed, LoRAConfig(rank=8, alpha=8.0),
                               OptimConfig(lr=3e-3), batch_size=8,
                               local_steps=2, seq_len=32)
    print(f"== federating {cfg.name} for {args.rounds} rounds ==")
    for rnd in range(args.rounds):
        rec = trainer.run_round(rnd)
        print(f"round {rnd}: eval_loss={rec.eval_loss:.4f} "
              f"download_rank={rec.download_rank:.0f}")

    # the aggregation result IS the deployable artifact: one global adapter
    global_adapters = trainer.global_state.global_adapters
    print("\n== serving base + global FLoRIST adapter ==")
    eng = ServeEngine(cfg, trainer.params, global_adapters,
                      batch_slots=args.batch_slots, capacity=64, seed=0,
                      decode_impl=args.decode_impl)
    rng = np.random.default_rng(0)
    sp = SamplingParams(temperature=args.temperature, top_k=8,
                        max_tokens=args.max_tokens)
    uids = [eng.submit(rng.integers(1, cfg.vocab_size, rng.integers(3, 9)).tolist(), sp)
            for _ in range(args.requests)]
    out = eng.run()
    for uid in uids:
        print(f"  req {uid}: {out[uid]}")
    print(f"served {len(out)} requests over {args.batch_slots} slots "
          f"(jitted step traces: {eng.trace_counts})")


if __name__ == "__main__":
    main()
