"""FLoRIST live round->deploy loop: federate, hot-swap, serve — concurrently.

The paper's output is not a one-shot artifact: every federated round produces
a NEW global adapter, and a deployment keeps serving while training continues.
This example runs that loop for real.  A single :class:`ServeEngine` stays up
the whole time, mounted on an :class:`AdapterRegistry`; after each round the
fresh ``global_adapters`` tree is published with ``registry.swap`` (an atomic
version bump: new pages, new id, name repointed) while requests admitted
against the PREVIOUS version keep decoding in their slots untouched.  Requests
submitted after the swap resolve to the new version, so for a few engine steps
both generations of the adapter serve side by side in one batch — and the
jitted step never retraces, because registry churn only rewrites fixed-shape
device pools.

With ``--mesh N`` the engine decodes tensor-parallel on a ``(data=1,
model=N)`` mesh — same tokens, same trace counts, the registry's paged
pools sharded along with the base weights.  On a CPU host export
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first (the flag is
read once, at backend init).

  PYTHONPATH=src python examples/serve_federated.py [--rounds 2] \
      [--requests-per-round 4] [--batch-slots 4] [--temperature 0.0] \
      [--mesh 0]
"""
import argparse

import numpy as np

from repro.common.config import FedConfig, LoRAConfig, ModelConfig, OptimConfig
from repro.core.federated import FederatedTrainer
from repro.serve.adapters import AdapterRegistry
from repro.serve.engine import SamplingParams, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--requests-per-round", type=int, default=2)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--overlap-steps", type=int, default=3,
                    help="engine steps run between publish and the next "
                         "round, so old/new adapter versions share a batch")
    ap.add_argument("--decode-impl", default="streamed",
                    choices=["dense", "streamed", "kernel"],
                    help="serving attention interior (streamed = "
                         "ring-flash-decode hot loop)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="model-parallel devices for the serve mesh "
                         "(0 = no mesh, single device)")
    args = ap.parse_args()

    cfg = ModelConfig(name="serve-fed-tiny", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=256, dtype="float32")
    fed = FedConfig(num_clients=8, clients_per_round=4, method="florist",
                    tau=0.9, homogeneous_rank=8, seed=0)
    trainer = FederatedTrainer(cfg, fed, LoRAConfig(rank=8, alpha=8.0),
                               OptimConfig(lr=3e-3), batch_size=8,
                               local_steps=2, seq_len=32)

    # One engine, up for the whole run — even before the first round lands
    # (every slot starts on base id 0).  The registry's paged pools are the
    # deploy surface; trainer rounds just publish into them.
    registry = AdapterRegistry(trainer.A_init_full, page_rank=4,
                               num_pages=16, max_adapters=8, max_rank=8)
    mesh = None
    if args.mesh:
        from repro.topology import make_serve_mesh
        mesh = make_serve_mesh(args.mesh)
    eng = ServeEngine(cfg, trainer.params, batch_slots=args.batch_slots,
                      capacity=64, seed=0, decode_impl=args.decode_impl,
                      registry=registry, mesh=mesh)
    rng = np.random.default_rng(0)
    sp = SamplingParams(temperature=args.temperature, top_k=8,
                        max_tokens=args.max_tokens)

    def submit_wave(n, adapter_id):
        return {eng.submit(rng.integers(1, cfg.vocab_size,
                                        rng.integers(3, 9)).tolist(),
                           sp, adapter_id=adapter_id): adapter_id
                for _ in range(n)}

    served_by = {}   # uid -> adapter id that served it
    outputs = {}     # uid -> generated tokens
    print(f"== live round->deploy loop: {cfg.name}, {args.rounds} rounds ==")
    for rnd in range(args.rounds):
        rec = trainer.run_round(rnd)
        # Publish this round's aggregate.  Round 0 registers the name;
        # later rounds swap — in-flight rows keep their old id's pages.
        if rnd == 0:
            new_id = registry.register("global", trainer.global_state.global_adapters)
        else:
            new_id = registry.swap("global", trainer.global_state.global_adapters)
        print(f"round {rnd}: eval_loss={rec.eval_loss:.4f} "
              f"download_rank={rec.download_rank:.0f} -> published id {new_id}"
              f" (live ids: {registry.live_ids})")

        served_by.update(submit_wave(args.requests_per_round, new_id))
        # Advance without draining: rows from the previous round's version
        # decode next to rows on the one just published.
        done = eng.run_steps(args.overlap_steps)
        outputs.update(done)
        in_flight = sorted({served_by[s.uid] for s in eng.slots
                            if s is not None})
        print(f"         batch now mixes adapter ids {in_flight} in flight")

    outputs.update(eng.run())
    for uid in sorted(outputs):
        print(f"  req {uid} [adapter id {served_by[uid]}]: {outputs[uid]}")
    by_id = {i: sum(1 for a in served_by.values() if a == i)
             for i in sorted(set(served_by.values()))}
    print(f"served {len(outputs)} requests across adapter versions {by_id} "
          f"over {args.batch_slots} slots")
    print(f"jitted step traces across {args.rounds} publishes: "
          f"{eng.trace_counts} (hot-swap never recompiles)")


if __name__ == "__main__":
    main()
